"""Deterministic serving fuzzer: random interleavings of submits, scheduler
steps, polls, and registry mutations across tenants, strategies, and QoS
scheduler policies — asserting that

* every answer is **bit-identical to a cold serial replay** of the same
  query against the table snapshot it was admitted on (the tentpole
  invariant: scheduling policy never changes answers, only who waits);
* ``ServingStats`` conservation holds at every step: submitted =
  queued + running + done + failed, one QueryRecord per finished session,
  no session ever lost.

Every case is seeded and fully deterministic (cost_model="unit", seeded
numpy rng, no wall-clock decisions).  On failure the seed and case config
are printed and embedded in the assertion message, so any CI failure is
reproducible with ``QUIP_FUZZ_SEED=<seed>``.  The fast profile runs in the
default suite; the deep profile (more seeds × the full policy × sharing
matrix, longer op streams) is behind ``@pytest.mark.slow`` (``--runslow``).
"""

from __future__ import annotations

from collections import Counter

import numpy as np
import pytest

from repro.analysis import lockcheck
from repro.core.env import env_int
from repro.core.executor import execute_offline, execute_quip
from repro.core.plan import Aggregate, Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.imputers.base import ImputationService
from repro.service import QuipService, TableRegistry
from test_quip_correctness import GroundTruthImputer, _build_instance

STRATEGIES = ("offline", "eager", "lazy", "adaptive")
STATES = {"queued", "running", "done", "failed"}
MORSEL_ROWS = 8

# extra seed injected by CI / a repro run: QUIP_FUZZ_SEED=123
# (env_int fails loud on a typo'd seed instead of silently fuzzing
# the default sweep)
_ENV_SEED = env_int("QUIP_FUZZ_SEED")


@pytest.fixture(autouse=True)
def _lock_sanitizer(monkeypatch):
    """Fuzz under the lock-order sanitizer: every service in the sweep uses
    instrumented locks, and teardown asserts the acquisition-order graph
    stayed acyclic (docs/analysis.md).  The replay invariant then also
    certifies the sanitizer changes no answers."""
    monkeypatch.setenv("QUIP_SANITIZE", "locks")
    lockcheck.reset()
    yield
    lockcheck.assert_acyclic()


def _rand_query(rng: np.random.Generator) -> Query:
    v = int(rng.integers(0, 6))
    kind = int(rng.integers(0, 3))
    if kind == 0:  # single-table scan+select
        table = f"R{int(rng.integers(0, 2))}"
        return Query((table,),
                     (SelectionPredicate(f"{table}.v", "<=", v),),
                     (), (f"{table}.v",))
    joins = (JoinPredicate("R0.k1", "R1.k1"),)
    sels = (SelectionPredicate("R0.v", "<=", v),)
    if kind == 1:  # join + projection
        return Query(("R0", "R1"), sels, joins, ("R0.v", "R1.v"))
    # join + aggregate
    op = ("count", "sum", "max")[int(rng.integers(0, 3))]
    return Query(("R0", "R1"), sels, joins, (),
                 aggregate=Aggregate(op, "R1.v"))


def _rand_mutation(rng: np.random.Generator, reg: TableRegistry) -> None:
    table = f"R{int(rng.integers(0, 2))}"
    n = reg[table].num_rows
    if n <= 8:
        return
    if rng.random() < 0.6:  # update a few values in the key domain
        k = int(rng.integers(1, 4))
        rows = rng.choice(n, size=k, replace=False).astype(np.int64)
        attr = f"{table}.v"
        vals = rng.integers(0, 6, size=k).astype(np.int64)
        reg.update_rows(table, rows, {attr: vals})
    else:
        k = int(rng.integers(1, 3))
        rows = rng.choice(n, size=k, replace=False).astype(np.int64)
        reg.delete_rows(table, rows)


def _replay(query: Query, strategy: str, snapshot, factory):
    """Cold serial replay on the admission-time snapshot — the oracle."""
    eng = ImputationService(
        {t: r.copy() for t, r in snapshot.items()}, default=factory
    )
    if strategy == "offline":
        return execute_offline(query, snapshot, eng)
    return execute_quip(query, snapshot, eng, strategy=strategy,
                        morsel_rows=MORSEL_ROWS)


def _fuzz_case(seed: int, policy: str, shared: bool, n_ops: int,
               rows: int = 40, mutations: bool = True,
               result_cache: int = 8) -> None:
    ctx = (f"[serving-fuzz] seed={seed} policy={policy} shared={shared} "
           f"n_ops={n_ops} mutations={mutations}")
    print(ctx)  # shown in pytest failure output → reproducible in CI
    rng = np.random.default_rng(seed)
    tables, _clean, truth = _build_instance(
        np.random.default_rng(seed + 1000), 2, rows, 0.3, 6
    )
    reg = TableRegistry({t: r.copy() for t, r in tables.items()})
    factory = lambda: GroundTruthImputer(truth)  # noqa: E731
    svc = QuipService(
        reg, factory, strategy="lazy", shared_impute=shared,
        max_inflight=3, morsel_rows=MORSEL_ROWS,
        scheduler_policy=policy, cost_model="unit",
        tenant_weights={0: 2.0}, tenant_deadlines={1: 64.0},
        tenant_quotas={2: 1}, result_cache_size=result_cache,
    )
    submitted = []  # (ticket, query, strategy, admission snapshot)

    def check_conservation():
        states = Counter(s.state for s in svc._sessions.values())
        assert set(states) <= STATES, f"{ctx} unknown state in {states}"
        assert sum(states.values()) == len(submitted), (
            f"{ctx} session lost: {states} vs {len(submitted)} submitted"
        )
        finished = states["done"] + states["failed"]
        assert len(svc.serving.records) == finished, (
            f"{ctx} record count {len(svc.serving.records)} != finished "
            f"{finished}"
        )

    for _ in range(n_ops):
        op = rng.random()
        if op < 0.45:
            query = _rand_query(rng)
            strategy = STRATEGIES[int(rng.integers(0, len(STRATEGIES)))]
            tenant = int(rng.integers(0, 3))
            # mutations only land on a drained service (below), so the
            # registry state at submit is exactly what admission will copy
            snapshot = {t: reg[t].copy() for t in query.tables}
            ticket = svc.submit(query, strategy=strategy, tenant=tenant)
            submitted.append((ticket, query, strategy, snapshot))
        elif op < 0.80:
            for _k in range(int(rng.integers(1, 5))):
                svc.step()
        elif op < 0.90 and submitted:
            ticket = submitted[int(rng.integers(0, len(submitted)))][0]
            assert svc.poll(ticket) in STATES, ctx
        elif mutations:
            # drain first: the shared store vetoes mid-flight mutations,
            # and a drained service keeps the admission-snapshot oracle
            # exact for queued-at-submit sessions too
            svc.run_until_idle()
            _rand_mutation(rng, reg)
        check_conservation()

    svc.run_until_idle()
    check_conservation()
    summary = svc.summary()
    assert summary["queries"] == len(submitted), ctx
    assert summary["failed"] == 0, (
        f"{ctx} unexpected failures: "
        f"{[r.ticket for r in svc.serving.records if r.failed]}"
    )
    assert {r.ticket for r in svc.serving.records} == \
        {t for t, _q, _s, _snap in submitted}, f"{ctx} ticket set mismatch"
    for ticket, query, strategy, snapshot in submitted:
        assert svc.poll(ticket) == "done", f"{ctx} ticket {ticket} not done"
        got = Counter(svc.answers(ticket))
        want = Counter(
            _replay(query, strategy, snapshot, factory).answer_tuples()
        )
        assert got == want, (
            f"{ctx} ticket {ticket} strategy={strategy} diverged from "
            f"cold serial replay"
        )


# --------------------------------------------------------------------------- #
# delta-mode profile (QUIP_IVM): patched answers == evicted-world answers
# --------------------------------------------------------------------------- #
def _gen_mutation(rng: np.random.Generator, reg: TableRegistry,
                  max_rows: int):
    """Draw mutation parameters *without* applying them, so the identical
    mutation can hit several registries (the IVM-on / IVM-off pair)."""
    table = f"R{int(rng.integers(0, 2))}"
    n = reg[table].num_rows
    if n <= 8:
        return None
    r = rng.random()
    if r < 0.5:
        k = int(rng.integers(1, 4))
        rows = rng.choice(n, size=k, replace=False).astype(np.int64)
        vals = rng.integers(0, 6, size=k).astype(np.int64)
        return ("update", table, rows, {f"{table}.v": vals})
    if r < 0.8 or n >= max_rows:
        k = int(rng.integers(1, 3))
        rows = rng.choice(n, size=k, replace=False).astype(np.int64)
        return ("delete", table, rows, None)
    # insert fully-present rows, never growing past the original row count:
    # the ground-truth oracle's arrays are indexed by tid
    k = int(rng.integers(1, min(3, max_rows - n + 1)))
    values = {a: rng.integers(0, 6, size=k).astype(np.int64)
              for a in reg[table].column_names()}
    return ("insert", table, None, values)


def _apply_mutation(reg: TableRegistry, mut) -> None:
    kind, table, rows, payload = mut
    if kind == "update":
        reg.update_rows(table, rows, payload)
    elif kind == "delete":
        reg.delete_rows(table, rows)
    else:
        reg.insert_rows(table, payload)


def _ivm_fuzz_case(seed: int, n_ops: int, rows: int = 40,
                   missing_rate: float = 0.0) -> None:
    """Twin services over identical data and mutation streams — IVM on vs
    off — plus the cold-replay oracle.  Asserts three-way bit-identical
    answers after every query and the maintenance accounting invariant:
    every cached answer that depended on a mutated table was either
    patched or evicted (``results_patched + ivm_fallbacks`` equals the
    dependent-entry count summed at mutation time)."""
    ctx = f"[ivm-fuzz] seed={seed} n_ops={n_ops} missing={missing_rate}"
    print(ctx)
    rng = np.random.default_rng(seed)
    tables, _clean, truth = _build_instance(
        np.random.default_rng(seed + 2000), 2, rows, missing_rate, 6
    )
    factory = lambda: GroundTruthImputer(truth)  # noqa: E731
    svcs, regs = {}, {}
    for mode, flag in (("on", True), ("off", False)):
        regs[mode] = TableRegistry({t: r.copy() for t, r in tables.items()})
        svcs[mode] = QuipService(
            regs[mode], factory, strategy="lazy", max_inflight=3,
            morsel_rows=MORSEL_ROWS, cost_model="unit",
            result_cache_size=32, ivm=flag,
        )
    dependents = 0  # cached entries depending on a mutated table, at commit
    for _ in range(n_ops):
        if rng.random() < 0.6:
            query = _rand_query(rng)
            strategy = STRATEGIES[int(rng.integers(0, len(STRATEGIES)))]
            answers = {}
            for mode, svc in svcs.items():
                ticket = svc.submit(query, strategy=strategy)
                svc.run_until_idle()
                answers[mode] = Counter(svc.answers(ticket))
            snapshot = {t: regs["on"][t].copy() for t in query.tables}
            cold = Counter(
                _replay(query, strategy, snapshot, factory).answer_tuples()
            )
            assert answers["on"] == cold, (
                f"{ctx} IVM-on diverged from cold replay for {query}"
            )
            assert answers["off"] == cold, (
                f"{ctx} IVM-off diverged from cold replay for {query}"
            )
        else:
            # services are drained after every submit, so mutations always
            # land on an idle pair and both registries stay in lockstep
            mut = _gen_mutation(rng, regs["on"], rows)
            if mut is None:
                continue
            dependents += len(
                svcs["on"].result_cache.keys_for_table(mut[1])
            )
            for mode in ("on", "off"):
                _apply_mutation(regs[mode], mut)
    s_on, s_off = svcs["on"].summary(), svcs["off"].summary()
    assert s_on["results_patched"] + s_on["ivm_fallbacks"] == dependents, (
        f"{ctx} accounting broke: patched={s_on['results_patched']} "
        f"fallbacks={s_on['ivm_fallbacks']} dependents={dependents} "
        f"reasons={dict(svcs['on']._ivm.fallback_reasons)}"
    )
    assert s_off["results_patched"] == 0 and s_off["ivm_fallbacks"] == 0, ctx
    assert s_on["queries"] == s_off["queries"], ctx
    if missing_rate == 0.0:
        # clean data: nothing imputed, so count/sum/select entries must
        # actually be *patched* (imputed_overlap cannot fire)
        assert s_on["results_patched"] > 0, (
            f"{ctx} no patches — reasons="
            f"{dict(svcs['on']._ivm.fallback_reasons)}"
        )
        assert "imputed_overlap" not in svcs["on"]._ivm.fallback_reasons, ctx


@pytest.mark.parametrize("seed,missing_rate", [
    (0, 0.0),
    (1, 0.0),
    (2, 0.3),
])
def test_serving_fuzz_ivm(seed, missing_rate):
    _ivm_fuzz_case(seed, n_ops=32, missing_rate=missing_rate)


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(3, 9)))
@pytest.mark.parametrize("missing_rate", [0.0, 0.3])
def test_serving_fuzz_ivm_deep(seed, missing_rate):
    _ivm_fuzz_case(seed, n_ops=90, rows=56, missing_rate=missing_rate)


# --------------------------------------------------------------------------- #
# fast profile: default suite
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed,policy,shared", [
    (0, "rr", False),
    (0, "wfq", False),
    (1, "deadline", False),
    (1, "wfq", True),
])
def test_serving_fuzz_fast(seed, policy, shared):
    _fuzz_case(seed, policy, shared, n_ops=36)


def test_serving_fuzz_result_cache_off():
    """Same invariants with the result cache disabled — every repeat
    re-executes, so scheduling interleave is maximal."""
    _fuzz_case(3, "wfq", False, n_ops=30, result_cache=0)


# --------------------------------------------------------------------------- #
# deep profile: --runslow (CI's slow job); QUIP_FUZZ_SEED adds a repro seed
# --------------------------------------------------------------------------- #
_DEEP_SEEDS = list(range(2, 8))
if _ENV_SEED is not None:
    _DEEP_SEEDS = [_ENV_SEED] + _DEEP_SEEDS


@pytest.mark.slow
@pytest.mark.parametrize("seed", _DEEP_SEEDS)
@pytest.mark.parametrize("policy", ["rr", "wfq", "deadline"])
@pytest.mark.parametrize("shared", [False, True])
def test_serving_fuzz_deep(seed, policy, shared):
    _fuzz_case(seed, policy, shared, n_ops=110, rows=56)
