"""quiplint passes + the runtime lock-order sanitizer (docs/analysis.md).

Three layers of coverage:

* **synthetic fixtures** — every lint pass both *flags* a minimal
  violation and *accepts* the sanctioned spellings (with-blocks,
  ``# requires:`` contracts, ``# unguarded:`` waivers, impl forwarding);
* **real-tree checks** — ``lint_repo()`` is clean on the shipped tree
  (the CI gate), and stays *sensitive*: perturbing the real sources
  (dropping a contract, renaming a lock, orphaning a span) re-introduces
  findings, so a green lint run means the passes are actually looking;
* **sanitizer** — a scripted 3-thread A→B / B→C / C→A inversion is
  reported as a potential deadlock (with the JSON artifact written),
  while consistent orderings, same-name key locks, reentrancy, and
  Condition ``wait()`` stay acyclic with honest held-sets.

Plus numpy/ref agreement smokes for the kernel paths the parity pass
pins (``bloom_probe`` / ``hash_join_match`` / ``masked_distance``).
"""

from __future__ import annotations

import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import lint, lockcheck
from repro.analysis.lint import PASSES, lint_repo, lint_sources
from repro.kernels import ops
from repro.kernels.hashing import fold64


def _msgs(findings):
    return [str(f) for f in findings]


# --------------------------------------------------------------------------- #
# env-discipline
# --------------------------------------------------------------------------- #
def test_env_pass_flags_direct_reads():
    src = (
        "import os\n"
        'a = os.environ["QUIP_TRACE"]\n'
        'b = os.environ.get("QUIP_TRACE")\n'
        'c = os.getenv("QUIP_TRACE")\n'
    )
    f = PASSES["env-discipline"]({"service/x.py": src})
    assert len(f) == 3, _msgs(f)
    assert all("QUIP_TRACE" in x.message for x in f)


def test_env_pass_flags_mutation_outside_launch_whitelist():
    src = 'import os\nos.environ["XLA_FLAGS"] = "x"\n'
    f = PASSES["env-discipline"]({"service/x.py": src})
    assert len(f) == 1 and "mutation" in f[0].message
    # the import-time launch shims are whitelisted
    assert PASSES["env-discipline"]({"launch/dryrun.py": src}) == []


def test_env_pass_flags_unregistered_knob():
    src = 'from repro.core.env import env_flag\nv = env_flag("QUIP_NOPE")\n'
    f = PASSES["env-discipline"]({"core/x.py": src})
    assert any("ENV_REGISTRY" in x.message for x in f)
    assert any("not a registered knob" in x.message for x in f)
    ok = 'from repro.core.env import env_flag\nv = env_flag("QUIP_TRACE")\n'
    assert PASSES["env-discipline"]({"core/x.py": ok}) == []


# --------------------------------------------------------------------------- #
# counter-discipline
# --------------------------------------------------------------------------- #
def test_counters_pass_flags_unknown_field():
    src = "def f(self):\n    self.counters.bogus_total += 1\n"
    f = PASSES["counter-discipline"]({"core/x.py": src})
    assert len(f) == 1 and "bogus_total" in f[0].message
    ok = "def f(self):\n    self.counters.join_tests += 1\n"
    assert PASSES["counter-discipline"]({"core/x.py": ok}) == []


def test_counters_pass_requires_provenance_mirror():
    bad = "def f(self):\n    self.counters.imputations += 3\n"
    f = PASSES["counter-discipline"]({"imputers/x.py": bad})
    assert len(f) == 1 and "on_flush" in f[0].message
    ok = (
        "def f(self):\n"
        "    self.counters.imputations += 3\n"
        "    self.provenance.on_flush(self, [], [], 0)\n"
    )
    assert PASSES["counter-discipline"]({"imputers/x.py": ok}) == []


# --------------------------------------------------------------------------- #
# lock-discipline
# --------------------------------------------------------------------------- #
_LOCK_FIXTURE = '''
class C:
    def __init__(self):
        self._q = []       # guarded-by: _lock
        self._n = 0        # guarded-by: _lock|_cv
        self._lock = object()

    def bad_mutator(self):
        self._q.append(1)

    def bad_subscript(self):
        self._q[0] = 2

    def good_with(self):
        with self._lock:
            self._q.append(1)
            del self._q[0]

    def good_alternative(self):
        with self._cv:
            self._n += 1

    def good_contract(self):  # requires: _lock
        self._q.append(2)

    def good_waiver(self):
        self._n = 5  # unguarded: test fixture waiver
'''


def test_locks_pass_fixture():
    f = PASSES["lock-discipline"]({"service/x.py": _LOCK_FIXTURE})
    lines = sorted(x.line for x in f)
    # exactly the two bad_* mutations; every sanctioned spelling accepted
    assert len(f) == 2, _msgs(f)
    assert all("guarded-by" in x.message for x in f)
    bad1 = _LOCK_FIXTURE.splitlines().index("        self._q.append(1)") + 1
    assert lines[0] == bad1


# --------------------------------------------------------------------------- #
# span-discipline
# --------------------------------------------------------------------------- #
def test_spans_pass_fixture():
    bad = (
        "def f(tracer):\n"
        '    tracer.span("x")\n'
        '    tracer.begin("y")\n'
    )
    f = PASSES["span-discipline"]({"obs/x.py": bad})
    # orphan span + discarded begin + module begins-without-end
    assert len(f) == 3, _msgs(f)
    ok = (
        "def f(tracer):\n"
        '    with tracer.span("x"):\n'
        "        pass\n"
        '    sp = tracer.span("y")\n'
        "    with sp:\n"
        "        pass\n"
        '    tid = tracer.begin("z")\n'
        "    tracer.end(tid)\n"
        "def g(tracer):\n"
        '    return tracer.span("caller-owned")\n'
    )
    assert PASSES["span-discipline"]({"obs/x.py": ok}) == []


# --------------------------------------------------------------------------- #
# kernel-parity
# --------------------------------------------------------------------------- #
_OPS_FIXTURE = '''
__all__ = ["op_full", "op_half", "op_bare", "op_forward", "resolve_t_impl"]

def resolve_t_impl(impl=None):
    return impl or env_choice("QUIP_TRACE", ("numpy", "ref", "pallas"), "numpy")

def op_full(x, impl=None):
    impl = resolve_t_impl(impl)
    if impl == "numpy":
        return x
    if impl == "pallas":
        return x
    return x

def op_half(x, impl=None):
    impl = resolve_t_impl(impl)
    if impl == "numpy":
        return x
    return x

def op_bare(x, impl=None):
    return x

def op_forward(x, impl=None):
    return op_full(x, impl=impl)
'''


def test_parity_pass_fixture():
    f = PASSES["kernel-parity"]({"kernels/ops.py": _OPS_FIXTURE})
    by_op = {x.message.split(" ")[1]: x.message for x in f}
    assert set(by_op) == {"op_half", "op_bare"}, _msgs(f)
    assert "'pallas'" in by_op["op_half"]
    assert "neither resolves" in by_op["op_bare"]
    # the pass only looks at kernels/ops.py
    assert PASSES["kernel-parity"]({"kernels/other.py": _OPS_FIXTURE}) == []


# --------------------------------------------------------------------------- #
# the real tree: clean, and the passes stay sensitive to perturbations
# --------------------------------------------------------------------------- #
def test_repo_lint_is_clean():
    assert lint_repo() == []


def _real_sources():
    return lint.load_sources(lint.find_repo_root())


def _perturb(sources, path, old, new):
    assert old in sources[path], f"perturbation anchor gone from {path}: {old!r}"
    sources[path] = sources[path].replace(old, new)
    return sources


def test_perturb_dropped_requires_contract_is_flagged():
    srcs = _perturb(_real_sources(), "imputers/base.py",
                    "# requires: flush_lock", "")
    f = [x for x in PASSES["lock-discipline"](srcs)
         if x.path == "imputers/base.py"]
    assert f and all("guarded-by" in x.message for x in f)


def test_perturb_renamed_lock_is_flagged():
    srcs = _perturb(_real_sources(), "obs/trace.py",
                    "with self._lock:", "with self._nolock:")
    f = [x for x in PASSES["lock-discipline"](srcs)
         if x.path == "obs/trace.py"]
    assert f, "tracer mutations outside the renamed lock were not flagged"


def test_perturb_orphaned_begin_is_flagged():
    srcs = _perturb(_real_sources(), "service/server.py",
                    "self.tracer.end(", "self.tracer.noop(")
    f = [x for x in PASSES["span-discipline"](srcs)
         if x.path == "service/server.py"]
    assert any("never tracer.end" in x.message for x in f)


def test_perturb_removed_waiver_is_flagged():
    srcs = _perturb(
        _real_sources(), "service/server.py",
        "  # unguarded: workers joined; no concurrent readers remain", "")
    f = [x for x in PASSES["lock-discipline"](srcs)
         if x.path == "service/server.py"]
    assert any("_pool" in x.message for x in f)


def test_lint_sources_reports_syntax_errors():
    f = lint_sources({"core/x.py": "def broken(:\n"})
    assert f and all("syntax error" in x.message for x in f)


def test_env_docs_render_roundtrip():
    text = ("head\n" + lint.DOCS_BEGIN + "\nstale\n" + lint.DOCS_END
            + "\ntail\n")
    rendered = lint.render_env_docs(text)
    assert lint.env_registry_table() in rendered
    assert lint.render_env_docs(rendered) == rendered  # idempotent
    assert lint.render_env_docs("no markers") is None


# --------------------------------------------------------------------------- #
# lock-order sanitizer
# --------------------------------------------------------------------------- #
@pytest.fixture
def sanitized(monkeypatch):
    monkeypatch.setenv("QUIP_SANITIZE", "locks")
    lockcheck.reset()
    yield
    lockcheck.reset()


def test_factories_plain_when_off(monkeypatch):
    monkeypatch.delenv("QUIP_SANITIZE", raising=False)
    assert type(lockcheck.make_lock("T.x")) is type(threading.Lock())
    assert type(lockcheck.make_rlock("T.x")) is type(threading.RLock())
    monkeypatch.setenv("QUIP_SANITIZE", "garbage")
    with pytest.raises(ValueError):
        lockcheck.make_lock("T.x")


@pytest.mark.timeout(30)
def test_three_thread_cycle_is_potential_deadlock(sanitized, tmp_path):
    a = lockcheck.make_lock("T.A")
    b = lockcheck.make_lock("T.B")
    c = lockcheck.make_lock("T.C")

    def order(first, second):
        with first:
            with second:
                pass

    # three threads, run to completion one after another: no interleaving
    # ever deadlocks, but the acquisition orders close the cycle A→B→C→A
    for pair in ((a, b), (b, c), (c, a)):
        t = threading.Thread(target=order, args=pair)
        t.start()
        t.join()

    rep = lockcheck.report()
    assert rep["cycles"], "cycle not detected from edge set"
    assert rep["potential_deadlocks"], "online detection missed the cycle"
    cyc = rep["potential_deadlocks"][0]
    assert len(cyc["edges"]) >= 2  # both sides of the inversion, with stacks
    assert all(e["stack"] for e in cyc["edges"])

    artifact = tmp_path / "lock_report.json"
    with pytest.raises(AssertionError, match="potential deadlock"):
        lockcheck.assert_acyclic(str(artifact))
    written = json.loads(artifact.read_text())
    assert written["cycles"] and written["mode"] == "locks"


@pytest.mark.timeout(30)
def test_consistent_order_stays_acyclic(sanitized):
    a = lockcheck.make_lock("T.A")
    b = lockcheck.make_lock("T.B")

    def ab():
        with a:
            with b:
                pass

    for _ in range(3):
        t = threading.Thread(target=ab)
        t.start()
        t.join()
    rep = lockcheck.assert_acyclic(artifact_path=None)
    edge = next(e for e in rep["edges"]
                if e["src"] == "T.A" and e["dst"] == "T.B")
    assert edge["count"] == 3  # stack captured once, count accumulated
    assert rep["locks"]["T.A"]["acquisitions"] == 3


def test_same_name_instances_share_a_node_without_self_edges(sanitized):
    k1 = lockcheck.make_lock("T.key")
    k2 = lockcheck.make_lock("T.key")
    with k1:
        with k2:
            pass
    rep = lockcheck.assert_acyclic(artifact_path=None)
    assert all(e["src"] != e["dst"] for e in rep["edges"])
    assert rep["locks"]["T.key"]["acquisitions"] == 2


def test_rlock_reentrancy_orders_only_at_outermost(sanitized):
    rl = lockcheck.make_rlock("T.R")
    other = lockcheck.make_lock("T.O")
    with rl:
        with rl:  # reentrant: no self-edge, depth bookkeeping only
            with other:
                pass
    rep = lockcheck.assert_acyclic(artifact_path=None)
    assert [  # one edge, from the rlock's 0→1 acquisition
        (e["src"], e["dst"]) for e in rep["edges"]
    ] == [("T.R", "T.O")]
    assert rep["locks"]["T.R"]["acquisitions"] == 1


def test_nonblocking_contention_recorded(sanitized):
    lk = lockcheck.make_lock("T.cont")
    got = []
    with lk:
        t = threading.Thread(
            target=lambda: got.append(lk.acquire(blocking=False)))
        t.start()
        t.join()
    assert got == [False]
    rep = lockcheck.report()
    assert rep["locks"]["T.cont"]["contended"] == 1


@pytest.mark.timeout(30)
def test_condition_wait_keeps_held_set_honest(sanitized):
    rl = lockcheck.make_rlock("T.cv_lock")
    cv = lockcheck.make_condition(rl)
    other = lockcheck.make_lock("T.other")
    ready = threading.Event()
    done = []

    def waiter():
        with cv:
            ready.set()
            cv.wait(timeout=10)
            # wait() released and reacquired through the graph: the only
            # edge the next acquire records is cv_lock→other, and the
            # held set is empty again once the with-block exits
            with other:
                done.append(True)

    t = threading.Thread(target=waiter)
    t.start()
    assert ready.wait(10)
    with cv:
        cv.notify_all()
    t.join(10)
    assert done == [True]
    rep = lockcheck.assert_acyclic(artifact_path=None)
    assert any(e["src"] == "T.cv_lock" and e["dst"] == "T.other"
               for e in rep["edges"])


# --------------------------------------------------------------------------- #
# numpy members of the kernel triples (the parity pass pins these exist)
# --------------------------------------------------------------------------- #
def test_bloom_probe_numpy_matches_ref():
    rng = np.random.default_rng(7)
    log2m, num_hashes = 14, 4
    bits = rng.integers(0, 2**32, (1 << log2m) // 32, dtype=np.uint32)
    keys = rng.integers(-(2**62), 2**62, 512).astype(np.int64)
    folded = fold64(keys)
    ref = np.asarray(ops.bloom_probe(
        jnp.asarray(bits), jnp.asarray(folded),
        num_hashes=num_hashes, log2m=log2m, impl="ref"))
    host = np.asarray(ops.bloom_probe(
        bits, folded, num_hashes=num_hashes, log2m=log2m, impl="numpy"))
    np.testing.assert_array_equal(ref, host)


def test_hash_join_numpy_matches_ref():
    rng = np.random.default_rng(11)
    b = rng.integers(0, 50, 200).astype(np.int64)
    p = rng.integers(0, 60, 300).astype(np.int64)  # some keys miss
    pi_r, bi_r = ops.hash_join_match(b, p, impl="ref")
    pi_n, bi_n = ops.hash_join_match(b, p, impl="numpy")
    np.testing.assert_array_equal(np.asarray(pi_r), pi_n)
    np.testing.assert_array_equal(np.asarray(bi_r), bi_n)


def test_masked_distance_numpy_matches_ref():
    rng = np.random.default_rng(13)
    q = rng.normal(size=(20, 6)).astype(np.float32)
    r = rng.normal(size=(30, 6)).astype(np.float32)
    qm = (rng.random((20, 6)) > 0.3).astype(np.float32)
    rm = (rng.random((30, 6)) > 0.3).astype(np.float32)
    dref = np.asarray(ops.masked_distance(q, qm, r, rm, impl="ref"))
    dnp = ops.masked_distance(q, qm, r, rm, impl="numpy")
    np.testing.assert_allclose(dref, dnp, rtol=1e-4, atol=1e-4)


def test_impl_resolvers_honor_env_knobs(monkeypatch):
    monkeypatch.setenv("QUIP_BLOOM_IMPL", "numpy")
    assert ops.resolve_bloom_impl() == "numpy"
    monkeypatch.setenv("QUIP_DIST_IMPL", "ref")
    assert ops.resolve_dist_impl() == "ref"
    with pytest.raises(ValueError):
        ops.resolve_join_impl("vectorwise")
    monkeypatch.delenv("QUIP_BLOOM_IMPL")
    assert ops.resolve_bloom_impl() in ("ref", "pallas")  # default_impl()
