"""QuipService serving layer: serial-vs-concurrent equivalence, plan-cache
behavior, cross-query imputation sharing, admission control, compound-query
routing, and the serving telemetry surface."""

from __future__ import annotations

from collections import Counter

import numpy as np
import pytest

from repro.core.executor import execute_offline, execute_quip
from repro.core.plan import Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.stats import nearest_rank_quantile
from repro.imputers.base import ImputationService, Imputer
from repro.service import (
    MorselScheduler,
    PlanCache,
    QuipService,
    TableRegistry,
    query_signature,
    resolve_shared_impute,
)
from test_quip_correctness import GroundTruthImputer, _build_instance

STRATEGIES = ["offline", "eager", "lazy", "adaptive"]


# --------------------------------------------------------------------------- #
# harness: an overlapping multi-query workload over one instance
# --------------------------------------------------------------------------- #
def _instance(seed=11, rows=64):
    rng = np.random.default_rng(seed)
    tables, clean, truth = _build_instance(rng, 2, rows, 0.3, 6)
    return tables, clean, truth


def _query(v, proj=("R0.v", "R1.v")):
    return Query(
        tables=("R0", "R1"),
        selections=(SelectionPredicate("R0.v", "<=", v),),
        joins=(JoinPredicate("R0.k1", "R1.k1"),),
        projection=proj,
    )


# hot template repeated (plan-cache hits + imputation overlap) + variations
WORKLOAD = [_query(2), _query(4), _query(2), _query(3), _query(2)]


def _serial_replay(queries, tables, truth, strategy, morsel_rows=8):
    """The cold-engine baseline: a fresh ImputationService per query."""
    out = []
    for q in queries:
        eng = ImputationService(
            {t: tables[t].copy() for t in tables},
            default=lambda: GroundTruthImputer(truth),
        )
        if strategy == "offline":
            out.append(execute_offline(q, tables, eng))
        else:
            out.append(execute_quip(q, tables, eng, strategy=strategy,
                                    morsel_rows=morsel_rows))
    return out


def _service(tables, truth, *, strategy="lazy", shared=False, inflight=3,
             **kw):
    return QuipService(
        tables, lambda: GroundTruthImputer(truth), strategy=strategy,
        shared_impute=shared, max_inflight=inflight, morsel_rows=8, **kw
    )


# --------------------------------------------------------------------------- #
# serial vs concurrent equivalence (isolation default)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_serial_vs_concurrent_equivalence(strategy):
    """Interleaved execution with per-query isolation must match serial
    replay: same per-query answers and the same total imputed values."""
    tables, _clean, truth = _instance()
    serial = _serial_replay(WORKLOAD, tables, truth, strategy)
    svc = _service(tables, truth, strategy=strategy)
    tickets = [svc.submit(q) for q in WORKLOAD]
    svc.run_until_idle()
    for tk, sr in zip(tickets, serial):
        assert Counter(svc.answers(tk)) == Counter(sr.answer_tuples())
    total = svc.serving.total_counters()
    assert total.imputations == sum(r.counters.imputations for r in serial)
    assert svc.summary()["queries"] == len(WORKLOAD)


# --------------------------------------------------------------------------- #
# plan cache
# --------------------------------------------------------------------------- #
def test_plan_cache_hits_on_repeated_signatures():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth)
    for q in WORKLOAD:
        svc.submit(q)
    svc.run_until_idle()
    # WORKLOAD has 3 distinct signatures (v=2 three times, v=4, v=3)
    assert svc.plan_cache.misses == 3
    assert svc.plan_cache.hits == 2
    assert svc.summary()["plan_cache_hits"] == 2


def test_query_signature_canonicalization():
    q1 = Query(("R0",), (SelectionPredicate("R0.v", "in",
                                            frozenset({3, 1, 2})),),
               (), ("R0.v",))
    q2 = Query(("R0",), (SelectionPredicate("R0.v", "in",
                                            frozenset({2, 3, 1})),),
               (), ("R0.v",))
    q3 = Query(("R0",), (SelectionPredicate("R0.v", "==", 1),), (), ("R0.v",))
    assert query_signature(q1) == query_signature(q2)
    assert query_signature(q1) != query_signature(q3)
    assert query_signature(q1, "naive") != query_signature(q1, "imputedb")


def test_plan_cache_lru_eviction():
    tables, _clean, truth = _instance()
    cache = PlanCache(capacity=2)
    qa, qb, qc = _query(1), _query(2), _query(3)
    for q in (qa, qb, qc):
        _plan, hit = cache.get(q, tables)
        assert not hit
    assert cache.evictions == 1 and len(cache) == 2
    _plan, hit = cache.get(qc, tables)  # most recent: still cached
    assert hit
    _plan, hit = cache.get(qa, tables)  # evicted: re-planned
    assert not hit


def test_cached_plan_is_cloned_per_execution():
    """Two sessions of the same signature must not share plan nodes — the
    executor mutates parent pointers and VF lists."""
    tables, _clean, truth = _instance()
    cache = PlanCache()
    p1, _ = cache.get(_query(2), tables)
    p2, _ = cache.get(_query(2), tables)
    assert p1 is not p2
    assert {id(n) for n in _walk(p1)}.isdisjoint({id(n) for n in _walk(p2)})


def _walk(node):
    for c in node.children:
        yield from _walk(c)
    yield node


# --------------------------------------------------------------------------- #
# cross-query imputation sharing
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", ["eager", "lazy"])
def test_shared_store_reduces_invocations(strategy):
    """On an overlapping workload the shared store must strictly reduce both
    imputer invocations and imputed values, with identical answers."""
    tables, _clean, truth = _instance()
    results = {}
    for shared in (False, True):
        svc = _service(tables, truth, strategy=strategy, shared=shared)
        tickets = [svc.submit(q) for q in WORKLOAD]
        svc.run_until_idle()
        answers = [Counter(svc.answers(t)) for t in tickets]
        results[shared] = (answers, svc.serving.total_counters())
    iso_answers, iso = results[False]
    sh_answers, sh = results[True]
    assert sh_answers == iso_answers  # bit-identical answers either way
    assert sh.imputations < iso.imputations
    assert sh.impute_batches < iso.impute_batches
    assert sh.impute_cross_hits > 0
    assert iso.impute_cross_hits == 0  # isolation: nobody else's cells


def test_shared_impute_env_gate(monkeypatch):
    monkeypatch.delenv("QUIP_SHARED_IMPUTE", raising=False)
    assert not resolve_shared_impute(None)  # isolation is the safe default
    monkeypatch.setenv("QUIP_SHARED_IMPUTE", "1")
    assert resolve_shared_impute(None)
    assert not resolve_shared_impute(False)  # explicit beats env
    tables, _clean, truth = _instance()
    assert _service(tables, truth, shared=None).shared_impute
    monkeypatch.setenv("QUIP_SHARED_IMPUTE", "0")
    assert not _service(tables, truth, shared=None).shared_impute


def test_shared_impute_env_gate_accepts_common_spellings(monkeypatch):
    """Regression: ``QUIP_SHARED_IMPUTE=true`` / ``yes`` used to silently
    disable sharing (only the literal "1" enabled it); garbage now raises
    instead of silently meaning off."""
    for raw in ("true", "yes", "ON"):
        monkeypatch.setenv("QUIP_SHARED_IMPUTE", raw)
        assert resolve_shared_impute(None)
    for raw in ("false", "no", "off"):
        monkeypatch.setenv("QUIP_SHARED_IMPUTE", raw)
        assert not resolve_shared_impute(None)
    monkeypatch.setenv("QUIP_SHARED_IMPUTE", "enable")
    with pytest.raises(ValueError, match="QUIP_SHARED_IMPUTE"):
        resolve_shared_impute(None)
    # QUIP_IMPUTE_BATCH goes through the same parser
    from repro.imputers.base import _resolve_batching

    monkeypatch.setenv("QUIP_IMPUTE_BATCH", "no")
    assert not _resolve_batching(None)
    monkeypatch.setenv("QUIP_IMPUTE_BATCH", "yes")
    assert _resolve_batching(None)
    monkeypatch.setenv("QUIP_IMPUTE_BATCH", "2")
    with pytest.raises(ValueError, match="QUIP_IMPUTE_BATCH"):
        _resolve_batching(None)


def test_shared_store_flush_guard():
    """The concurrent-flush discipline fails loud on reentrant flushes."""
    tables, _clean, truth = _instance()

    class ReentrantImputer(Imputer):
        def __init__(self, svc_box):
            self.box = svc_box

        def impute_attr(self, table, attr, tids):
            self.box[0].enqueue("R1", "R1.v", np.array([0]))
            self.box[0].flush()  # flush-within-flush
            return np.zeros(len(tids))

    from repro.service.impute_store import SharedImputeStore

    store = SharedImputeStore({t: r.copy() for t, r in tables.items()})
    box = []
    svc = store.bind(lambda: ReentrantImputer(box))
    box.append(svc)
    with pytest.raises(RuntimeError, match="flush"):
        svc.impute("R0", "R0.v", np.array([0, 1]))


# --------------------------------------------------------------------------- #
# admission control + scheduling
# --------------------------------------------------------------------------- #
def test_admission_limit_respected():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, inflight=2)
    for q in WORKLOAD + WORKLOAD[:1]:
        svc.submit(q)
        assert svc.scheduler.running <= 2
    states = Counter(svc.poll(t) for t in range(1, 7))
    assert states["running"] == 2 and states["queued"] == 4
    while svc.step():
        assert svc.scheduler.running <= 2
    summary = svc.summary()
    assert summary["max_concurrent"] == 2
    assert summary["admission_queued"] == 4
    assert all(svc.poll(t) == "done" for t in range(1, 7))


def test_round_robin_interleaves_sessions():
    """The scheduler must not run one multi-morsel query to completion
    before starting the next (no head-of-line blocking)."""
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, inflight=2)
    t1, t2 = svc.submit(_query(4)), svc.submit(_query(3))
    finish_order = []
    first_runs = {t1: None, t2: None}
    steps = 0
    while svc.scheduler.running or svc._waiting:
        head = svc.scheduler._ring[0].ticket if svc.scheduler._ring else None
        if head is not None and first_runs[head] is None:
            first_runs[head] = steps
        if not svc.step():
            break
        steps += 1
    # both sessions got their first step before either finished
    assert None not in first_runs.values()
    assert max(first_runs.values()) < steps


def test_failed_session_surfaces_error():
    tables, _clean, truth = _instance()

    class BoomImputer(Imputer):
        def impute_attr(self, table, attr, tids):
            raise RuntimeError("imputer exploded")

    svc = QuipService(tables, BoomImputer, strategy="eager", morsel_rows=8)
    ok = svc.submit(_query(4))  # runs but needs imputations → fails
    svc.run_until_idle()
    assert svc.poll(ok) == "failed"
    with pytest.raises(RuntimeError, match="exploded"):
        svc.result(ok)


def test_latency_and_queue_wait_telemetry():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, inflight=1)
    for q in WORKLOAD[:3]:
        svc.submit(q)
    svc.run_until_idle()
    recs = svc.serving.records
    assert len(recs) == 3
    assert all(r.latency_s > 0 for r in recs)
    # with inflight=1 the later submissions waited for the head query
    assert recs[-1].queue_wait_s > 0
    assert svc.serving.latency_quantile(0.95) >= svc.serving.latency_quantile(0.5)


# --------------------------------------------------------------------------- #
# compound (§9.3) queries through the service
# --------------------------------------------------------------------------- #
def test_compound_queries_match_extensions():
    from repro.core.extensions import (
        execute_minus,
        execute_nested,
        execute_union,
    )

    tables, _clean, truth = _instance()
    factory = lambda: ImputationService(
        {t: tables[t].copy() for t in tables},
        default=lambda: GroundTruthImputer(truth),
    )
    l, r = _query(4), _query(2)
    outer = Query(("R0",), (), (), ("R0.v",))
    sub = Query(("R1",), (SelectionPredicate("R1.v", "<=", 2),), (),
                ("R1.k1",))

    want_u, stats_u = execute_union(l, r, tables, factory, strategy="lazy")
    want_m, _ = execute_minus(l, r, tables, factory, strategy="lazy")
    want_n, _ = execute_nested(outer, "R0.k1", sub, tables, factory,
                               strategy="lazy")

    # default morsel_rows: execute_* runs whole-relation morsels, and morsel
    # size legitimately changes imputation counts (bloom-completion pruning)
    svc = QuipService(tables, lambda: GroundTruthImputer(truth),
                      strategy="lazy")
    got_u, svc_stats_u = svc.result(svc.submit_union(l, r))
    got_m, _ = svc.result(svc.submit_minus(l, r))
    got_n, _ = svc.result(svc.submit_nested(outer, "R0.k1", sub))
    assert Counter(got_u) == Counter(want_u)
    assert got_m == want_m
    assert Counter(got_n) == Counter(want_n)
    # both report the full merged counters, and identical work was done
    for key in ("imputations", "impute_batches", "impute_flushes",
                "join_impl"):
        assert svc_stats_u[key] == stats_u[key]


def test_compound_tickets_poll_and_answers():
    """Compound tickets work through the same poll/answers surface as
    plain ones (regression: they used to KeyError)."""
    tables, _clean, truth = _instance()
    svc = _service(tables, truth)
    t_u = svc.submit_union(_query(4), _query(2))
    assert svc.poll(t_u) in ("queued", "running")
    answers = svc.answers(t_u)
    assert svc.poll(t_u) == "done"
    assert answers and answers == svc.result(t_u)[0]


def test_release_drops_finished_tickets():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth)
    t1 = svc.submit(_query(3))
    t_u = svc.submit_union(_query(4), _query(2))
    with pytest.raises(AssertionError):
        svc.release(t1)  # unfinished
    svc.run_until_idle()
    svc.result(t1), svc.result(t_u)
    svc.release(t1)
    svc.release(t_u)  # also drops its branch sessions
    assert not svc._sessions and not svc._compounds
    assert len(svc.serving.records) == 3  # telemetry retained


def test_failed_compound_branch_stops_rescanning():
    """A compound whose branch failed leaves the pending scan set and
    surfaces the branch error via poll/result."""
    tables, _clean, truth = _instance()

    class BoomImputer(Imputer):
        def impute_attr(self, table, attr, tids):
            raise RuntimeError("branch exploded")

    svc = QuipService(tables, BoomImputer, strategy="eager", morsel_rows=8)
    t_u = svc.submit_union(_query(4), _query(2))
    svc.run_until_idle()
    assert svc.poll(t_u) == "failed"
    assert not svc._pending_compounds
    with pytest.raises(RuntimeError, match="exploded"):
        svc.result(t_u)


def test_nested_empty_subquery_via_service():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth)
    outer = Query(("R0",), (), (), ("R0.v",))
    sub = Query(("R1",), (SelectionPredicate("R1.v", "<=", -10 ** 6),), (),
                ("R1.k1",))
    answers, _stats = svc.result(svc.submit_nested(outer, "R0.k1", sub))
    assert answers == []


# --------------------------------------------------------------------------- #
# serving workload generator
# --------------------------------------------------------------------------- #
def test_serving_workload_skewed_stream():
    from repro.data.queries import serving_workload
    from repro.data.synthetic import wifi_dataset
    from repro.service.plan_cache import query_signature

    tables, _ = wifi_dataset(n_users=50, n_wifi=300, n_occ=150)
    stream = list(serving_workload("wifi", tables, n_queries=30,
                                   n_templates=5, n_tenants=3, seed=3))
    assert len(stream) == 30
    tenants = {t for t, _q in stream}
    assert tenants <= set(range(3)) and len(tenants) > 1
    sigs = Counter(query_signature(q) for _t, q in stream)
    assert len(sigs) <= 5  # drawn from the template pool
    assert max(sigs.values()) > 30 // 5  # skew: hot template over-represented
    # deterministic for a fixed seed
    again = list(serving_workload("wifi", tables, n_queries=30,
                                  n_templates=5, n_tenants=3, seed=3))
    assert [query_signature(q) for _t, q in stream] == \
        [query_signature(q) for _t, q in again]


def test_mutating_workload_stream():
    """Deterministic query/mutation interleaving whose mutations apply
    cleanly against a TableRegistry (row ids stay valid as deletes
    shrink tables)."""
    from repro.data.queries import mutating_workload
    from repro.data.synthetic import wifi_dataset

    tables, _ = wifi_dataset(n_users=50, n_wifi=300, n_occ=150)
    events = list(mutating_workload("wifi", tables, n_queries=20,
                                    mutate_every=4, n_templates=5, seed=3))
    kinds = Counter(e[0] for e in events)
    assert kinds["query"] == 20 and kinds["mutate"] >= 4
    muts = [e[1] for e in events if e[0] == "mutate"]
    assert {m.kind for m in muts} == {"update_rows", "delete_rows"}
    again = list(mutating_workload("wifi", tables, n_queries=20,
                                   mutate_every=4, n_templates=5, seed=3))
    assert muts == [e[1] for e in again if e[0] == "mutate"]
    reg = TableRegistry({t: r.copy() for t, r in tables.items()})
    for e in events:
        if e[0] == "mutate":
            e[1].apply(reg)
    assert reg.global_epoch == kinds["mutate"]


def test_scheduler_drain_empty():
    sched = MorselScheduler()
    assert sched.drain() == [] and sched.running == 0


# --------------------------------------------------------------------------- #
# result cache (epoch-keyed answer reuse)
# --------------------------------------------------------------------------- #
def test_result_cache_hit_skips_execution():
    """A repeated signature submitted after the first completed must be
    answered from the cache: done immediately, same answers, zero new
    relational work."""
    tables, _clean, truth = _instance()
    svc = _service(tables, truth)
    first = svc.answers(svc.submit(_query(2)))
    imputations_before = svc.serving.total_counters().imputations
    t2 = svc.submit(_query(2))
    assert svc.poll(t2) == "done"  # no scheduling needed
    assert svc.answers(t2) == first
    total = svc.serving.total_counters()
    assert total.imputations == imputations_before  # no work re-ran
    summary = svc.summary()
    assert summary["result_cache_hits"] == 1
    assert summary["queries_result_cache_hit"] == 1
    # the hit never consulted the planner
    assert svc.plan_cache.hits == 0 and svc.plan_cache.misses == 1


def test_result_cache_respects_exec_knobs():
    """Same signature under a different strategy is a different key."""
    tables, _clean, truth = _instance()
    svc = _service(tables, truth)
    a = svc.answers(svc.submit(_query(2), strategy="lazy"))
    b = svc.answers(svc.submit(_query(2), strategy="eager"))
    assert Counter(a) == Counter(b)
    assert svc.summary()["result_cache_hits"] == 0


def test_result_cache_disabled_with_size_zero():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, result_cache_size=0)
    svc.answers(svc.submit(_query(2)))
    svc.answers(svc.submit(_query(2)))
    assert svc.result_cache is None
    assert "result_cache_hits" not in svc.summary()
    assert svc.plan_cache.hits == 1  # plans still shared


# --------------------------------------------------------------------------- #
# registry mutation: epochs + invalidation across every cache
# --------------------------------------------------------------------------- #
def test_mutation_invalidates_result_and_plan_caches():
    tables, _clean, truth = _instance()
    reg = TableRegistry({t: r.copy() for t, r in tables.items()})
    svc = _service(reg, truth)
    stale = svc.answers(svc.submit(_query(2)))
    assert len(svc.plan_cache) == 1 and len(svc.result_cache) == 1
    # flip every R0.v to 0: the <=2 selection now passes all R0 rows
    reg.update_rows("R0", np.arange(64),
                    {"R0.v": np.zeros(64, dtype=np.int64)})
    assert len(svc.plan_cache) == 0 and len(svc.result_cache) == 0
    fresh = svc.answers(svc.submit(_query(2)))
    assert fresh != stale  # the mutation is visible, not the cached answer
    cold = _service({t: reg[t].copy() for t in reg}, truth,
                    result_cache_size=0)
    assert Counter(fresh) == Counter(cold.answers(cold.submit(_query(2))))
    summary = svc.summary()
    assert summary["invalidation_events"] == 1
    assert summary["plans_invalidated"] == 1
    assert summary["results_invalidated"] == 1
    assert summary["registry_epoch"] == 1
    assert summary["result_cache_hits"] == 0


MUTATIONS = [
    lambda reg: reg.update_rows(
        "R0", np.array([0, 3, 5]),
        {"R0.v": np.array([1, 2, 0], dtype=np.int64)}),
    lambda reg: reg.delete_rows("R1", np.array([2, 7, 11])),
    lambda reg: reg.update_rows(
        "R1", np.array([1, 4]), {"R1.k1": np.array([0, 3],
                                                   dtype=np.int64)}),
    lambda reg: reg.delete_rows("R0", np.array([0, 1, 2])),
]


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("shared", [False, True])
def test_mutation_equivalence_vs_cold_service(strategy, shared):
    """The tentpole acceptance invariant: after every mutation epoch, a
    long-lived service (plan cache + result cache + optionally shared
    impute store) answers bit-identically to a cold QuipService built on
    the post-mutation registry — no stale plan, imputation, or cached
    answer leaks.  The repeated signature in the round exercises the
    result cache within each epoch."""
    tables, _clean, truth = _instance()
    reg = TableRegistry({t: r.copy() for t, r in tables.items()})
    svc = _service(reg, truth, strategy=strategy, shared=shared)
    rounds = [_query(2), _query(4), _query(2)]  # repeat → cache hit
    for mutate in [None] + MUTATIONS:
        if mutate is not None:
            mutate(reg)
        got = [Counter(svc.answers(svc.submit(q))) for q in rounds]
        cold = _service({t: reg[t].copy() for t in reg}, truth,
                        strategy=strategy, shared=False,
                        result_cache_size=0)
        want = [Counter(cold.answers(cold.submit(q))) for q in rounds]
        assert got == want
    assert reg.global_epoch == len(MUTATIONS)
    assert svc.summary()["invalidation_events"] == len(MUTATIONS)
    if shared:
        # mutations dropped affected store cells along the way
        assert svc.serving.store_cells_invalidated > 0


def test_shared_store_mutation_vetoed_while_inflight():
    """Mutating a table that running shared-impute sessions read would mix
    epochs inside one query — the registry's before-hook must refuse,
    committing nothing; after draining, the mutation goes through."""
    tables, _clean, truth = _instance()
    reg = TableRegistry({t: r.copy() for t, r in tables.items()})
    svc = _service(reg, truth, shared=True)
    svc.submit(_query(2))  # admitted → RUNNING in the scheduler ring
    with pytest.raises(RuntimeError, match="drain"):
        reg.delete_rows("R0", np.array([0]))
    assert reg.global_epoch == 0 and reg["R0"].num_rows == 64
    svc.run_until_idle()
    reg.delete_rows("R0", np.array([0]))
    assert reg.global_epoch == 1


def test_isolated_sessions_keep_their_admission_snapshot():
    """Without a shared store, mutations during a query's run don't disturb
    it: admitted sessions own point-in-time table copies."""
    tables, _clean, truth = _instance()
    reg = TableRegistry({t: r.copy() for t, r in tables.items()})
    svc = _service(reg, truth, shared=False)
    want = svc.answers(svc.submit(_query(2)))  # pre-mutation answer
    t2 = svc.submit(_query(2), strategy="eager")  # admitted: snapshot taken
    for _ in range(3):
        svc.step()
    reg.update_rows("R0", np.arange(64),
                    {"R0.v": np.zeros(64, dtype=np.int64)})
    assert Counter(svc.answers(t2)) == Counter(want)
    rec = svc.serving.records[-1]
    assert not rec.failed


# --------------------------------------------------------------------------- #
# failed admission under pressure (regression: no QueryRecord landed)
# --------------------------------------------------------------------------- #
def test_failed_admission_reclaims_slot_and_records():
    """A query that fails inside start() (unknown table → plan error) never
    enters the ring; the admission slot must be reclaimed so the queue
    behind it drains, poll() must say failed, and a QueryRecord must land
    in ServingStats."""
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, inflight=1, result_cache_size=0)
    good1 = svc.submit(_query(4))
    bad = svc.submit(Query(("NOPE",), (), (), ("NOPE.v",)))
    good2 = svc.submit(_query(3))
    assert svc.poll(bad) == "queued"  # stuck behind good1 (max_inflight=1)
    svc.run_until_idle()
    assert svc.poll(bad) == "failed"
    assert svc.poll(good1) == "done" and svc.poll(good2) == "done"
    with pytest.raises(KeyError):
        svc.result(bad)
    # the failure is telemetry, not a silent drop
    records = {r.ticket: r for r in svc.serving.records}
    assert set(records) == {good1, bad, good2}
    assert records[bad].failed and not records[good1].failed
    summary = svc.summary()
    assert summary["queries"] == 3 and summary["failed"] == 1


def test_failed_admission_immediate_when_slot_free():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth)
    bad = svc.submit(Query(("NOPE",), (), (), ("NOPE.v",)))
    assert svc.poll(bad) == "failed"  # admission ran setup synchronously
    assert svc.serving.records[-1].failed


# --------------------------------------------------------------------------- #
# QoS: per-tenant scheduling, quotas, deadlines, shutdown with a queue
# --------------------------------------------------------------------------- #
def test_close_cancels_admission_queue():
    """Queued-but-never-admitted sessions must land a failed QueryRecord on
    close(), not vanish — the PR 4 "failures are telemetry" rule extended
    to shutdown.  The running session is untouched and still drains."""
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, inflight=1, result_cache_size=0)
    t1 = svc.submit(_query(4))
    t2 = svc.submit(_query(3))
    t3 = svc.submit(_query(2))
    assert svc.poll(t2) == "queued" and svc.poll(t3) == "queued"
    svc.close()
    assert svc.poll(t2) == "failed" and svc.poll(t3) == "failed"
    with pytest.raises(RuntimeError, match="closed"):
        svc.result(t2)
    svc.run_until_idle()  # the admitted head still completes
    assert svc.poll(t1) == "done"
    records = {r.ticket: r for r in svc.serving.records}
    assert set(records) == {t1, t2, t3}
    assert records[t2].failed and records[t3].failed
    assert not records[t1].failed
    assert records[t2].queue_wait_s >= 0
    summary = svc.summary()
    assert summary["queries"] == 3 and summary["failed"] == 2


def test_scheduler_drain_ignores_admission_queue():
    """MorselScheduler.drain() only completes *admitted* sessions; the
    service-level waiting queue is the server's to cancel (close()) or
    admit (step/run_until_idle) — no session is silently lost either way."""
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, inflight=1, result_cache_size=0)
    t1 = svc.submit(_query(4))
    t2 = svc.submit(_query(3))
    finished = svc.scheduler.drain()
    assert [s.ticket for s in finished] == [t1]
    assert svc.poll(t2) == "queued"  # still waiting, not dropped
    svc._finalize(finished[0])  # drain() bypasses the server's finalize
    svc.run_until_idle()  # admission resumes; t2 runs to completion
    assert svc.poll(t2) == "done"
    assert {r.ticket for r in svc.serving.records} == {t1, t2}


def test_tenant_quota_limits_concurrent_admissions():
    """A tenant at its quota waits even with free global slots, and does
    not head-of-line-block other tenants queued behind it."""
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, inflight=3, result_cache_size=0,
                   tenant_quotas={7: 1})
    a1 = svc.submit(_query(4), tenant=7)
    a2 = svc.submit(_query(3), tenant=7)  # quota-blocked
    b1 = svc.submit(_query(2), tenant=8)  # admitted past the blocked one
    assert svc.poll(a1) == "running"
    assert svc.poll(a2) == "queued"
    assert svc.poll(b1) == "running"
    assert svc.scheduler.tenant_running(7) == 1
    assert svc.summary()["admission_queued"] == 1
    while svc.step():
        assert svc.scheduler.tenant_running(7) <= 1
    assert all(svc.poll(t) == "done" for t in (a1, a2, b1))


def test_tenant_quota_below_one_rejected():
    """Regression: a quota of 0 could never admit its tenant's sessions —
    run_until_idle would spin forever on the unadmittable queue."""
    tables, _clean, truth = _instance()
    with pytest.raises(ValueError, match="quota"):
        _service(tables, truth, tenant_quotas={7: 0})
    with pytest.raises(ValueError, match="default_tenant_quota"):
        _service(tables, truth, default_tenant_quota=-1)


def test_wfq_victim_share_improves_over_round_robin():
    """End-to-end aggressor scenario: under unit-cost accounting the
    victim tenant's morsel-step share while it is active improves from
    ~1/(sessions) under rr to ~1/2 under wfq — deterministically."""
    def run(policy):
        tables, _clean, truth = _instance(rows=96)
        svc = _service(tables, truth, strategy="lazy", inflight=6,
                       result_cache_size=0, scheduler_policy=policy,
                       cost_model="unit")
        for _ in range(5):  # aggressor floods
            svc.submit(_query(5), tenant=0)
        victim = svc.submit(_query(5), tenant=1)
        svc.run_until_idle()
        rec = next(r for r in svc.serving.records if r.ticket == victim)
        # share of all scheduler steps granted while the victim was in
        # the system — clock units == steps under the unit model
        return rec.steps / rec.turnaround_cost
    rr_share = run("rr")
    wfq_share = run("wfq")
    assert wfq_share > rr_share
    assert wfq_share >= 0.4  # ~half while both tenants active


def test_deadline_policy_end_to_end_telemetry():
    """Deadline classes are clocked in cost units for every policy, and
    tenant_summary surfaces hit-rates, shares and turnaround."""
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, strategy="lazy", inflight=4,
                   result_cache_size=0, scheduler_policy="deadline",
                   cost_model="unit", tenant_deadlines={1: 500.0})
    svc.submit(_query(4), tenant=0)
    tv = svc.submit(_query(2), tenant=1)
    svc.run_until_idle()
    rec = next(r for r in svc.serving.records if r.ticket == tv)
    assert rec.deadline_met is True
    assert rec.steps > 0 and rec.sched_cost == pytest.approx(rec.steps)
    ts = svc.tenant_summary()
    assert set(ts) == {0, 1}
    assert ts[1]["deadline_hit_rate"] == 1.0
    assert ts[0]["deadline_hit_rate"] is None  # no class configured
    assert ts[0]["cost_share"] + ts[1]["cost_share"] == pytest.approx(1.0)
    assert ts[1]["p95_turnaround_cost"] > 0
    summary = svc.summary()
    assert summary["tenants"] == 2
    assert summary["scheduler_policy"] == "deadline"
    assert summary["morsel_steps"] == summary["sched_cost"]  # unit model


def test_answers_policy_independent_quick():
    """The tentpole invariant in miniature: same workload, all three
    policies, answers bit-identical (the fuzzer covers the full matrix)."""
    tables, _clean, truth = _instance()
    results = {}
    for policy in ("rr", "wfq", "deadline"):
        svc = _service(tables, truth, strategy="adaptive",
                       scheduler_policy=policy, cost_model="unit",
                       result_cache_size=0)
        tickets = [svc.submit(q, tenant=i % 2)
                   for i, q in enumerate(WORKLOAD)]
        svc.run_until_idle()
        results[policy] = [Counter(svc.answers(t)) for t in tickets]
    assert results["rr"] == results["wfq"] == results["deadline"]


# --------------------------------------------------------------------------- #
# serving workload: tenant skew + per-tenant template mixes
# --------------------------------------------------------------------------- #
def test_serving_workload_default_stream_unchanged():
    """Regression: with tenant_skew/tenant_mix unset the stream is
    byte-identical to the legacy generator (draw order preserved)."""
    import numpy as np

    from repro.data.queries import serving_workload, workload
    from repro.data.synthetic import wifi_dataset

    tables, _ = wifi_dataset(n_users=50, n_wifi=300, n_occ=150)
    n_queries, n_templates, n_tenants, skew, seed = 25, 5, 3, 1.1, 3
    got = list(serving_workload("wifi", tables, n_queries=n_queries,
                                n_templates=n_templates,
                                n_tenants=n_tenants, seed=seed))
    # the pre-QoS generator, replayed verbatim
    templates = workload("wifi", tables, kind="random",
                         n_queries=n_templates, seed=seed)
    rng = np.random.default_rng(seed + 7)
    ranks = np.arange(1, n_templates + 1, dtype=np.float64)
    probs = ranks ** -float(skew)
    probs /= probs.sum()
    want = []
    for _ in range(n_queries):
        t_idx = int(rng.choice(n_templates, p=probs))
        tenant = int(rng.integers(0, n_tenants))
        want.append((tenant, templates[t_idx]))
    assert [(t, query_signature(q)) for t, q in got] == \
        [(t, query_signature(q)) for t, q in want]


def test_serving_workload_tenant_skew_and_mix():
    from repro.data.queries import serving_workload
    from repro.data.synthetic import wifi_dataset

    tables, _ = wifi_dataset(n_users=50, n_wifi=300, n_occ=150)
    stream = list(serving_workload(
        "wifi", tables, n_queries=60, n_templates=5, n_tenants=3, seed=3,
        tenant_skew=2.0, tenant_mix={0: (0, 1), 2: (4,)},
    ))
    tenants = Counter(t for t, _q in stream)
    # zipf over tenants: tenant 0 is the aggressor issuing most queries
    assert tenants[0] > tenants[1] >= tenants[2]
    sigs_by_tenant = {
        t: {query_signature(q) for tt, q in stream if tt == t}
        for t in tenants
    }
    from repro.data.queries import workload as _workload
    pool = [query_signature(q) for q in _workload(
        "wifi", tables, kind="random", n_queries=5, seed=3)]
    assert sigs_by_tenant[0] <= {pool[0], pool[1]}  # pinned to its mix
    assert sigs_by_tenant[2] <= {pool[4]}
    # deterministic for a fixed seed
    again = list(serving_workload(
        "wifi", tables, n_queries=60, n_templates=5, n_tenants=3, seed=3,
        tenant_skew=2.0, tenant_mix={0: (0, 1), 2: (4,)},
    ))
    assert [(t, query_signature(q)) for t, q in stream] == \
        [(t, query_signature(q)) for t, q in again]
    with pytest.raises(ValueError, match="tenant_mix"):
        list(serving_workload("wifi", tables, n_queries=1, n_templates=5,
                              n_tenants=2, tenant_mix={0: (9,)}))
    # a mix entry for a tenant that can never be drawn is a config bug,
    # not a silently-dead pinning
    with pytest.raises(ValueError, match="outside range"):
        list(serving_workload("wifi", tables, n_queries=1, n_templates=5,
                              n_tenants=2, tenant_mix={2: (4,)}))


# --------------------------------------------------------------------------- #
# nearest-rank quantile (regression: banker's-rounded index)
# --------------------------------------------------------------------------- #
def test_nearest_rank_quantile_small_n():
    """p50 of 4 values is the 2nd order statistic (ceil(0.5·4) = 2); the
    old round(q·(n-1)) returned the 3rd."""
    assert nearest_rank_quantile([1.0, 2.0, 3.0, 4.0], 0.5) == 2.0
    assert nearest_rank_quantile([4.0, 1.0, 3.0, 2.0], 0.5) == 2.0
    assert nearest_rank_quantile([1.0, 2.0], 0.5) == 1.0
    assert nearest_rank_quantile([7.0], 0.95) == 7.0
    assert nearest_rank_quantile([], 0.5) == 0.0
    values = [float(i) for i in range(1, 21)]
    # p95 of 20 values: ceil(0.95·20) = 19th order statistic
    assert nearest_rank_quantile(values, 0.95) == 19.0
    assert nearest_rank_quantile(values, 0.0) == 1.0
    assert nearest_rank_quantile(values, 1.0) == 20.0
