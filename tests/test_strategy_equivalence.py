"""Executor strategy equivalence (paper §5 correctness invariant): the
answer multiset of ``offline`` == ``eager`` == ``lazy`` == ``adaptive`` on
small synthetic instances, with both the NumPy and the kernel-backed join
paths (``join_impl`` ∈ {numpy, ref, pallas}), and — for every strategy —
under ``QUIP_EXEC_IMPL=compiled`` (docs/compiled.md): eligible plans lower
to the vectorized whole-relation program, ineligible ones fall back to the
interpreter, and either way answers AND imputation counts stay
bit-identical to the default path."""

from __future__ import annotations

from collections import Counter

import numpy as np
import pytest

from repro.core.executor import evaluate_clean, execute_offline, execute_quip
from repro.core.plan import Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.imputers.base import ImputationEngine
from test_quip_correctness import GroundTruthImputer, _build_instance

STRATEGIES = ["offline", "eager", "lazy", "adaptive"]
JOIN_IMPLS = ["numpy", "ref", "pallas"]


def _instance(seed: int, n_tables: int):
    rng = np.random.default_rng(seed)
    tables, clean, truth = _build_instance(rng, n_tables, 24, 0.3, 5)
    q = Query(
        tables=tuple(f"R{i}" for i in range(n_tables)),
        selections=(SelectionPredicate("R0.v", "<=", 3),),
        joins=tuple(
            JoinPredicate(f"R{i}.k{i+1}", f"R{i+1}.k{i+1}")
            for i in range(n_tables - 1)
        ),
        projection=tuple(f"R{i}.v" for i in range(n_tables)),
    )
    engine_factory = lambda: ImputationEngine(
        {t: tables[t].copy() for t in tables},
        default=lambda: GroundTruthImputer(truth),
    )
    return tables, clean, q, engine_factory


@pytest.mark.parametrize("join_impl", JOIN_IMPLS)
@pytest.mark.parametrize(
    "seed,n_tables",
    [
        (11, 2),
        # 3-table chain: extra interpret-mode compiles make it ~10× slower;
        # the 2-table cases already cover every join path per impl
        pytest.param(23, 3, marks=pytest.mark.slow),
    ],
)
def test_all_strategies_agree(join_impl, seed, n_tables):
    tables, clean, q, engine_factory = _instance(seed, n_tables)
    expected = Counter(evaluate_clean(q, clean).to_sorted_tuples())

    answers = {}
    for strategy in STRATEGIES:
        if strategy == "offline":
            res = execute_offline(q, tables, engine_factory())
        else:
            res = execute_quip(
                q, tables, engine_factory(), strategy=strategy,
                morsel_rows=12, join_impl=join_impl,
            )
            assert res.counters.join_impl == join_impl
        answers[strategy] = Counter(res.answer_tuples())

    for strategy, got in answers.items():
        assert got == expected, (strategy, join_impl)


@pytest.mark.parametrize("join_impl", ["ref", "pallas"])
def test_kernel_join_path_matches_numpy_counters(join_impl):
    """Same instance, same strategy: kernel-backed join path must produce
    identical answers AND identical imputation counts as the NumPy path
    (the dispatch must not change decision-function behaviour)."""
    tables, _clean, q, engine_factory = _instance(42, 2)
    base = execute_quip(
        q, tables, engine_factory(), strategy="lazy", morsel_rows=16,
        join_impl="numpy",
    )
    other = execute_quip(
        q, tables, engine_factory(), strategy="lazy", morsel_rows=16,
        join_impl=join_impl,
    )
    assert other.answer_tuples() == base.answer_tuples()
    assert other.counters.imputations == base.counters.imputations
    assert other.counters.join_tests == base.counters.join_tests


@pytest.mark.parametrize("use_vf", [True, False])
@pytest.mark.parametrize("strategy", STRATEGIES + ["imputedb"])
def test_compiled_exec_matches_interp(strategy, use_vf, monkeypatch):
    """The full strategy matrix under ``QUIP_EXEC_IMPL=compiled``.

    Only eager (and its ``imputedb`` alias, which forces ``use_vf=False``
    itself) with the VF list off is lowering-eligible; every other cell
    must take the interpreter fallback.  In *all* cells the answers and
    the deduplicated imputation count must be bit-identical to the default
    interpreter run — the compiled path is an optimization, never a
    semantics change."""
    tables, _clean, q, engine_factory = _instance(17, 2)

    def run(exec_env):
        if exec_env is None:
            monkeypatch.delenv("QUIP_EXEC_IMPL", raising=False)
        else:
            monkeypatch.setenv("QUIP_EXEC_IMPL", exec_env)
        engine = engine_factory()
        if strategy == "offline":
            return execute_offline(q, tables, engine)
        return execute_quip(
            q, tables, engine, strategy=strategy, morsel_rows=12,
            use_vf=use_vf,
        )

    base = run(None)
    compiled = run("compiled")
    assert Counter(compiled.answer_tuples()) == Counter(base.answer_tuples())
    assert compiled.counters.imputations == base.counters.imputations

    if strategy == "offline":
        return  # never consults a plan — nothing to lower or fall back from
    eligible = strategy == "imputedb" or (strategy == "eager" and not use_vf)
    if eligible:
        assert compiled.counters.exec_impl == "compiled"
        assert compiled.counters.compiled_hits == 1
        assert compiled.counters.compile_fallbacks == 0
        # the batched pre-pass is the speedup lever: one flush per
        # (operator, attr) instead of one per (morsel, attr)
        assert (compiled.counters.impute_batches
                <= base.counters.impute_batches)
    else:
        assert compiled.counters.exec_impl == "interp"
        assert compiled.counters.compile_fallbacks == 1
        assert compiled.counters.compiled_hits == 0
