"""Executor strategy equivalence (paper §5 correctness invariant): the
answer multiset of ``offline`` == ``eager`` == ``lazy`` == ``adaptive`` on
small synthetic instances, with both the NumPy and the kernel-backed join
paths (``join_impl`` ∈ {numpy, ref, pallas})."""

from __future__ import annotations

from collections import Counter

import numpy as np
import pytest

from repro.core.executor import evaluate_clean, execute_offline, execute_quip
from repro.core.plan import Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.imputers.base import ImputationEngine
from test_quip_correctness import GroundTruthImputer, _build_instance

STRATEGIES = ["offline", "eager", "lazy", "adaptive"]
JOIN_IMPLS = ["numpy", "ref", "pallas"]


def _instance(seed: int, n_tables: int):
    rng = np.random.default_rng(seed)
    tables, clean, truth = _build_instance(rng, n_tables, 24, 0.3, 5)
    q = Query(
        tables=tuple(f"R{i}" for i in range(n_tables)),
        selections=(SelectionPredicate("R0.v", "<=", 3),),
        joins=tuple(
            JoinPredicate(f"R{i}.k{i+1}", f"R{i+1}.k{i+1}")
            for i in range(n_tables - 1)
        ),
        projection=tuple(f"R{i}.v" for i in range(n_tables)),
    )
    engine_factory = lambda: ImputationEngine(
        {t: tables[t].copy() for t in tables},
        default=lambda: GroundTruthImputer(truth),
    )
    return tables, clean, q, engine_factory


@pytest.mark.parametrize("join_impl", JOIN_IMPLS)
@pytest.mark.parametrize(
    "seed,n_tables",
    [
        (11, 2),
        # 3-table chain: extra interpret-mode compiles make it ~10× slower;
        # the 2-table cases already cover every join path per impl
        pytest.param(23, 3, marks=pytest.mark.slow),
    ],
)
def test_all_strategies_agree(join_impl, seed, n_tables):
    tables, clean, q, engine_factory = _instance(seed, n_tables)
    expected = Counter(evaluate_clean(q, clean).to_sorted_tuples())

    answers = {}
    for strategy in STRATEGIES:
        if strategy == "offline":
            res = execute_offline(q, tables, engine_factory())
        else:
            res = execute_quip(
                q, tables, engine_factory(), strategy=strategy,
                morsel_rows=12, join_impl=join_impl,
            )
            assert res.counters.join_impl == join_impl
        answers[strategy] = Counter(res.answer_tuples())

    for strategy, got in answers.items():
        assert got == expected, (strategy, join_impl)


@pytest.mark.parametrize("join_impl", ["ref", "pallas"])
def test_kernel_join_path_matches_numpy_counters(join_impl):
    """Same instance, same strategy: kernel-backed join path must produce
    identical answers AND identical imputation counts as the NumPy path
    (the dispatch must not change decision-function behaviour)."""
    tables, _clean, q, engine_factory = _instance(42, 2)
    base = execute_quip(
        q, tables, engine_factory(), strategy="lazy", morsel_rows=16,
        join_impl="numpy",
    )
    other = execute_quip(
        q, tables, engine_factory(), strategy="lazy", morsel_rows=16,
        join_impl=join_impl,
    )
    assert other.answer_tuples() == base.answer_tuples()
    assert other.counters.imputations == base.counters.imputations
    assert other.counters.join_tests == base.counters.join_tests
